"""The unified frontend layer: ``repro.trace``, the frontend registry,
and first-class multi-input/multi-output signatures.

Covers the acceptance criteria of the frontend PR: a traced function
and the identical ``ModelBuilder`` model are bit-identical on every
target; a two-output traced model round-trips ``serialize`` /
``deserialize`` with its ``Signature`` intact; model construction is
incremental (no per-layer full shape inference); and bare callables /
``.npz`` containers compile straight through ``repro.compile``.
"""

import os
import warnings

import numpy as np
import pytest

import repro
from repro.core import Graph, ModelBuilder, Signature, TensorSpec
from repro.frontends import (Frontend, available_frontends, ops as F,
                             register_frontend)
from repro.frontends.container import load_model, save_model
from repro.frontends.trace import TraceError

TARGETS = ("interpret", "jit", "pallas")


def _builder_cnn():
    """Reference model built through ModelBuilder; returns (graph, params)."""
    mb = ModelBuilder().seed(3)
    x = mb.input((8, 8, 3), name="image")
    h = mb.conv2d(x, 8, (3, 3), activation="relu")    # conv2d_1, act_relu_2
    h = mb.batchnorm(h)                               # bn_3
    h = mb.maxpool(h)
    h = mb.global_avg_pool(h)
    out = mb.dense(h, 4, activation="tanh")           # dense_6, act_tanh_7
    return mb.build([out]), mb.graph.params, out


def _traced_cnn(params):
    """The same model as a plain function over the same weight arrays."""

    def fn(image):
        h = F.conv2d(image, params["conv2d_1/kernel"],
                     params["conv2d_1/bias"], activation="relu")
        h = F.batchnorm(h, params["bn_3/gamma"], params["bn_3/beta"],
                        params["bn_3/mean"], params["bn_3/var"])
        h = F.maxpool(h)
        h = F.global_avg_pool(h)
        return F.dense(h, params["dense_6/kernel"], params["dense_6/bias"],
                       activation="tanh")

    return repro.trace(fn, (8, 8, 3))


# ---------------------------------------------------------------- tracing
def test_trace_matches_builder_bit_identical_on_every_target(rng):
    """Acceptance: trace(fn) and the identical ModelBuilder model give
    bit-identical outputs on interpret, jit and pallas."""
    g1, params, out = _builder_cnn()
    g2 = _traced_cnn(params)
    x = rng.standard_normal((2, 8, 8, 3)).astype(np.float32)
    for target in TARGETS:
        opts = repro.CompileOptions(target=target)
        want = np.asarray(repro.compile(g1, opts)(image=x)[out])
        got = np.asarray(repro.compile(g2, opts)(image=x)["output"])
        np.testing.assert_array_equal(got, want, err_msg=target)


def test_trace_signature_from_function():
    g = _traced_cnn(_builder_cnn()[1])
    sig = g.signature()
    assert isinstance(sig, Signature)
    assert sig.input_names == ("image",)          # from the fn's parameter
    assert sig.outputs == (("output", TensorSpec((4,))),)


def test_trace_operators_and_constants(rng):
    w = rng.standard_normal((6, 3)).astype(np.float32)

    def fn(a, b):
        h = (a + b) * 2.0 + np.float32(1.0)       # tensor+tensor, scalar lift
        return h @ w                              # matmul -> dense

    g = repro.trace(fn, (6,), (6,))
    assert g.signature().input_names == ("a", "b")
    a = rng.standard_normal((3, 6)).astype(np.float32)
    b = rng.standard_normal((3, 6)).astype(np.float32)
    got = np.asarray(
        repro.compile(g, target="interpret")(a=a, b=b)["output"])
    np.testing.assert_allclose(got, ((a + b) * 2.0 + 1.0) @ w, rtol=1e-5)


def test_trace_shared_weight_interned_once(rng):
    w = rng.standard_normal((4, 4)).astype(np.float32)

    def fn(x):
        return F.dense(F.dense(x, w), w)          # weight tying

    g = repro.trace(fn, (4,))
    assert sum(1 for p in g.params if p.endswith("/kernel")) == 1


def test_trace_numpy_left_operand(rng):
    """ndarray * TracedTensor must defer to the tracer (one mul node),
    not let numpy broadcast elementwise over the abstract tensor."""
    w = rng.standard_normal(4).astype(np.float32)

    def fn(x):
        return w * x + w                          # numpy on the LEFT

    g = repro.trace(fn, (4,))
    assert [n.op for n in g.nodes] == ["constant", "mul", "constant", "add"]
    x = rng.standard_normal((2, 4)).astype(np.float32)
    got = np.asarray(repro.compile(g, target="interpret")(x)["output"])
    np.testing.assert_allclose(got, w * x + w, rtol=1e-6)


def test_trace_distinct_temporary_weights_not_aliased(rng):
    """Two distinct weight *temporaries* must intern as two params even
    if CPython recycles the first one's id() after it is copied+freed
    (the id-keyed weight-tying memo must keep its keys alive)."""

    def fn(x):
        # float64 -> both arrays are copied to float32 inside the
        # tracer and the originals become collectable temporaries
        h = F.dense(x, np.ones((4, 4)))
        return F.dense(h, np.zeros((4, 4)))

    g = repro.trace(fn, (4,))
    kernels = [p for p in g.params if p.endswith("/kernel")]
    assert len(kernels) == 2
    x = np.ones((1, 4), np.float32)
    got = np.asarray(repro.compile(g, target="interpret")(x)["output"])
    np.testing.assert_array_equal(got, np.zeros((1, 4), np.float32))


def test_trace_rejects_data_dependent_control_flow():
    def fn(x):
        if x:                                      # truth value of abstract
            return x
        return x

    with pytest.raises(TraceError, match="branch"):
        repro.trace(fn, (4,))


def test_trace_rejects_foreign_and_non_tensor_outputs():
    with pytest.raises(TraceError, match="return"):
        repro.trace(lambda x: 3.0, (4,))
    leaked = None

    def capture(x):
        nonlocal leaked
        leaked = x
        return F.relu(x)

    repro.trace(capture, (4,))
    with pytest.raises(TraceError, match="different trace"):
        repro.trace(lambda x: x + leaked, (4,))


# --------------------------------------------------- multi-output end to end
def _two_head(rng):
    k = rng.standard_normal((3, 3, 3, 8)).astype(np.float32)
    w1 = rng.standard_normal((8, 4)).astype(np.float32)
    w2 = rng.standard_normal((8, 2)).astype(np.float32)

    def fn(image):
        h = F.global_avg_pool(F.conv2d(image, k, activation="relu"))
        return {"probs": F.softmax(F.dense(h, w1)),
                "embed": F.dense(h, w2)}

    return repro.trace(fn, (8, 8, 3))


def test_two_head_goldens_across_targets(rng):
    g = _two_head(rng)
    x = rng.standard_normal((2, 8, 8, 3)).astype(np.float32)
    ref = repro.compile(g, target="interpret")(x)
    assert list(ref) == ["probs", "embed"]        # user names, user order
    for target in ("jit", "pallas"):
        got = repro.compile(g, target=target)(x)
        assert list(got) == ["probs", "embed"]
        for name in ref:
            np.testing.assert_allclose(np.asarray(got[name]),
                                       np.asarray(ref[name]),
                                       rtol=2e-5, atol=1e-6,
                                       err_msg=f"{target}:{name}")


def test_two_head_serialize_round_trip_preserves_signature(rng):
    """Acceptance: a two-output traced model round-trips through
    serialize/deserialize with its Signature intact."""
    g = _two_head(rng)
    exe = repro.compile(g, target="jit")
    assert exe.signature.output_names == ("probs", "embed")
    exe2 = repro.deserialize(exe.serialize())
    assert exe2.signature == exe.signature
    x = rng.standard_normal((1, 8, 8, 3)).astype(np.float32)
    a, b = exe(x), exe2(x)
    assert list(a) == list(b) == ["probs", "embed"]
    for name in a:
        np.testing.assert_array_equal(np.asarray(a[name]),
                                      np.asarray(b[name]))


def test_container_round_trip_preserves_output_names(rng, tmp_path):
    g = _two_head(rng)
    path = str(tmp_path / "two_head.npz")
    save_model(g, path)
    g2 = load_model(path)
    assert g2.output_names == ["probs", "embed"]
    assert g2.outputs == g.outputs
    assert g2.signature() == g.signature()


def test_signature_in_cache_key(rng):
    """Renaming outputs must change the persistent-cache key: the
    public contract is part of what is cached."""
    g = _two_head(rng)
    g2 = g.copy()
    g2.set_outputs(dict(zip(["p2", "e2"], g.outputs)))
    k1 = repro.compile(g, target="jit")._key(1)
    k2 = repro.compile(g2, target="jit")._key(1)
    assert k1 != k2


def test_positional_or_keyword_binding(rng):
    w = rng.standard_normal((3, 2)).astype(np.float32)
    g = repro.trace(lambda a, b: (a + b) @ w, (3,), (3,))
    exe = repro.compile(g, target="jit")
    a = rng.standard_normal((2, 3)).astype(np.float32)
    b = rng.standard_normal((2, 3)).astype(np.float32)
    want = np.asarray(exe(a=a, b=b)["output"])
    np.testing.assert_array_equal(np.asarray(exe(a, b)["output"]), want)
    np.testing.assert_array_equal(np.asarray(exe(a, b=b)["output"]), want)
    with pytest.raises(TypeError, match="multiple values"):
        exe(a, a=a, b=b)
    with pytest.raises(TypeError, match="positional"):
        exe(a, b, a)
    with pytest.raises(ValueError, match="missing inputs"):
        exe(a)
    with pytest.raises(TypeError, match="unexpected inputs"):
        exe(a, b, c=a)


# ------------------------------------------------------------ the registry
def test_compile_bare_callable_with_example_inputs(rng):
    w = rng.standard_normal((4, 2)).astype(np.float32)
    x = rng.standard_normal((3, 4)).astype(np.float32)
    exe = repro.compile(lambda v: F.relu(v @ w), example_inputs=(x,),
                        target="jit")
    np.testing.assert_allclose(np.asarray(exe(x)["output"]),
                               np.maximum(x @ w, 0), rtol=1e-5)
    with pytest.raises(TypeError, match="example_inputs"):
        repro.compile(lambda v: v)                # no shapes to trace with


def test_compile_unknown_model_lists_frontends():
    with pytest.raises(TypeError) as ei:
        repro.compile(42)
    msg = str(ei.value)
    for name in available_frontends():
        assert name in msg


def test_compile_builder_and_container_frontends(rng, tmp_path):
    mb = ModelBuilder().seed(0)
    out = mb.dense(mb.input((4,)), 2)
    with pytest.raises(TypeError, match="outputs"):
        repro.compile(mb)                         # outputs not set yet
    exe = repro.compile(mb, outputs=[out], target="jit")
    x = rng.standard_normal((2, 4)).astype(np.float32)
    want = np.asarray(exe(x)[out])

    path = str(tmp_path / "m.npz")
    save_model(mb.graph, path)
    exe2 = repro.compile(path, target="jit")      # container frontend
    np.testing.assert_array_equal(np.asarray(exe2(x)[out]), want)

    # frontend options that the chosen frontend does not consume are
    # rejected, not silently ignored
    with pytest.raises(TypeError, match="example_inputs"):
        repro.compile(path, example_inputs=(x,))


def test_register_custom_frontend(rng):
    """Third-party model formats plug in exactly like targets/passes."""

    class LinearSpec(dict):
        pass

    @register_frontend("linear-spec")
    class LinearFrontend(Frontend):
        def accepts(self, model):
            return isinstance(model, LinearSpec)

        def to_graph(self, model, **kw):
            return repro.trace(lambda x: x @ model["w"],
                               model["in_shape"])

    try:
        assert "linear-spec" in available_frontends()
        w = rng.standard_normal((3, 2)).astype(np.float32)
        exe = repro.compile(LinearSpec(w=w, in_shape=(3,)), target="jit")
        x = rng.standard_normal((2, 3)).astype(np.float32)
        np.testing.assert_allclose(np.asarray(exe(x)["output"]),
                                   x @ w, rtol=1e-5)
    finally:
        from repro import frontends
        frontends._FRONTENDS.pop("linear-spec", None)


def test_keras_like_shims_warn_once():
    import repro.core.keras_like as kl
    g = repro.trace(lambda x: F.relu(x), (4,))
    import io
    kl._warned = False
    buf = io.BytesIO()
    with pytest.warns(DeprecationWarning, match="frontends.container"):
        kl.save_model(g, buf)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        buf.seek(0)
        kl.load_model(buf)
    assert not any(issubclass(w.category, DeprecationWarning)
                   for w in caught)


# ------------------------------------------------ incremental construction
def test_builder_construction_is_incremental(monkeypatch):
    """The O(n²) fix: building N layers runs shape inference O(N) times
    total, not O(N) times per layer."""
    calls = {"n": 0}
    orig = Graph._infer_node

    def counting(self, node, specs):
        calls["n"] += 1
        return orig(self, node, specs)

    monkeypatch.setattr(Graph, "_infer_node", counting)
    layers = 30
    mb = ModelBuilder().seed(0)
    h = mb.input((16,))
    for _ in range(layers):
        h = mb.dense(h, 16, activation="relu")
    mb.build([h])
    # one incremental inference per node (dense+activation per layer),
    # not a full re-walk per layer (which would be quadratic: >900)
    assert calls["n"] <= 2 * layers + 5


def test_spec_cache_invalidated_on_mutation():
    mb = ModelBuilder().seed(0)
    h = mb.dense(mb.input((4,)), 6)
    g = mb.build([h])
    assert g.spec(h).shape == (6,)
    # out-of-band mutation: widen the kernel, then rebuild_index —
    # the cache must not serve the stale (6,) spec
    g.params["dense_1/kernel"] = np.zeros((4, 8), np.float32)
    g.params["dense_1/bias"] = np.zeros(8, np.float32)
    g.rebuild_index()
    assert g.spec(h).shape == (8,)
    assert g.infer_shapes()[h].shape == (8,)


def test_builder_named_multi_outputs(rng):
    mb = ModelBuilder().seed(1)
    x = mb.input((6,))
    a = mb.dense(x, 3)
    b = mb.dense(x, 2)
    g = mb.build({"left": a, "right": b})
    assert g.output_names == ["left", "right"]
    exe = repro.compile(g, target="interpret")
    out = exe(rng.standard_normal((1, 6)).astype(np.float32))
    assert list(out) == ["left", "right"]
    assert out["left"].shape == (1, 3) and out["right"].shape == (1, 2)
