"""Serving engine: continuous batching, determinism, norm-fold."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.inference import Engine, Request, fold_norms
from repro.models import get_model


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2.5-14b", smoke=True)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def test_fold_norms_preserves_logits(setup):
    cfg, m, params = setup
    batch = {"tokens": jnp.arange(16, dtype=jnp.int32)[None, :] % cfg.vocab}
    l0, _ = m.forward(params, batch)
    folded, rep = fold_norms(cfg, params)
    l1, _ = m.forward(folded, batch)
    assert rep["folds"] > 0
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=2e-2, atol=2e-2)
    # gammas zeroed
    assert float(jnp.abs(folded["layers"]["ln1"]).max()) == 0.0


def test_fold_norms_moe():
    cfg = get_config("deepseek-v3-671b", smoke=True)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.arange(16, dtype=jnp.int32)[None, :] % cfg.vocab}
    l0, _ = m.forward(params, batch)
    folded, rep = fold_norms(cfg, params)
    l1, _ = m.forward(folded, batch)
    assert rep["folds"] >= 7
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=5e-2, atol=5e-2)


def test_engine_drains_queue(setup):
    cfg, m, params = setup
    eng = Engine(m, params, slots=2, max_len=48)
    for i in range(5):
        eng.submit(Request(uid=i, prompt=np.arange(4 + i) % cfg.vocab,
                           max_new_tokens=5))
    done = eng.run()
    assert sorted(c.uid for c in done) == list(range(5))
    assert all(len(c.tokens) == 5 for c in done)


def test_batched_equals_solo(setup):
    cfg, m, params = setup
    prompt = np.arange(6) % cfg.vocab
    solo = Engine(m, params, slots=1, max_len=48)
    solo.submit(Request(uid=0, prompt=prompt, max_new_tokens=6))
    want = solo.run()[0].tokens

    crowd = Engine(m, params, slots=3, max_len=48)
    for i in range(4):
        crowd.submit(Request(uid=i, prompt=prompt if i == 2 else
                             (np.arange(3 + i) * 7) % cfg.vocab,
                             max_new_tokens=6))
    got = [c for c in crowd.run() if c.uid == 2][0].tokens
    assert want == got


def test_eos_stops_generation(setup):
    cfg, m, params = setup
    eng = Engine(m, params, slots=1, max_len=48)
    # Find the first greedy token, then use it as EOS for a second run.
    eng.submit(Request(uid=0, prompt=np.arange(5) % cfg.vocab,
                       max_new_tokens=4))
    first = eng.run()[0].tokens
    eng2 = Engine(m, params, slots=1, max_len=48)
    eng2.submit(Request(uid=0, prompt=np.arange(5) % cfg.vocab,
                        max_new_tokens=32, eos_id=int(first[1])))
    out = eng2.run()[0].tokens
    assert out[-1] == first[1] and len(out) <= 32


def test_engine_cache_donation_structure(setup):
    """After many steps the cache pytree keeps its structure/shape."""
    cfg, m, params = setup
    eng = Engine(m, params, slots=2, max_len=48)
    eng.submit(Request(uid=0, prompt=np.arange(4) % cfg.vocab,
                       max_new_tokens=12))
    eng.run()
    assert eng.cache["c1"].shape[1] == 2      # slots preserved
