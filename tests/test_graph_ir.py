"""Graph IR: construction, shape inference, toposort, hashing."""

import numpy as np
import pytest

from repro.core import Graph, ModelBuilder
from repro.core.graph import TensorSpec


def small_graph():
    mb = ModelBuilder()
    x = mb.input((8, 8, 3))
    h = mb.conv2d(x, 4, (3, 3), activation="relu")
    h = mb.batchnorm(h)
    h = mb.maxpool(h)
    h = mb.flatten(h)
    h = mb.dense(h, 10)
    return mb.build([h]), x, h


def test_shape_inference():
    g, x, out = small_graph()
    specs = g.infer_shapes()
    assert specs[out].shape == (10,)
    assert specs[x].shape == (8, 8, 3)


def test_duplicate_names_rejected():
    g = Graph()
    g.add_input("a", (4,))
    with pytest.raises(ValueError):
        g.add_input("a", (4,))
    g.add_param("w", np.zeros((4, 4), np.float32))
    with pytest.raises(ValueError):
        g.add_param("w", np.zeros((2, 2), np.float32))


def test_unknown_tensor_rejected():
    g = Graph()
    g.add_input("a", (4,))
    with pytest.raises(ValueError):
        g.add_node("add", "bad", ["a", "nonexistent"])


def test_toposort_detects_disorder():
    g, _, _ = small_graph()
    order = g.toposort()
    assert len(order) == len(g.nodes)
    # shuffle nodes; toposort must still produce a valid order
    g.nodes = list(reversed(g.nodes))
    order = g.toposort()
    seen = set(g.inputs)
    for n in order:
        assert all(t in seen for t in n.inputs)
        seen.add(n.output)


def test_structure_hash_ignores_weights_but_not_shape():
    g1, _, _ = small_graph()
    g2, _, _ = small_graph()
    assert g1.structure_hash() == g2.structure_hash()
    g2.params[next(iter(g2.params))] += 1.0   # weight values: no change
    assert g1.structure_hash() == g2.structure_hash()
    mb = ModelBuilder()
    x = mb.input((8, 8, 3))
    h = mb.conv2d(x, 8, (3, 3))               # different width
    g3 = mb.build([h])
    assert g1.structure_hash() != g3.structure_hash()


def test_conv_padding_variants():
    for padding, expect in [("same", (8, 8)), ("valid", (6, 6)),
                            (((2, 2), (1, 1)), (10, 8))]:
        mb = ModelBuilder()
        x = mb.input((8, 8, 3))
        h = mb.conv2d(x, 4, (3, 3), padding=padding)
        g = mb.build([h])
        assert g.infer_shapes()[h].shape[:2] == expect


def test_tensor_spec_sizes():
    t = TensorSpec((4, 4, 2), "float32")
    assert t.size == 32 and t.nbytes == 128
