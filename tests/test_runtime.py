"""repro.runtime — shape buckets, the engine cache, bucketed
executables and shape-polymorphic serving.

Unit layers (policy arithmetic, EngineCache state machine) run with
fake builds and fake clocks; integration layers assert the two load-
bearing equivalences bit-for-bit: a dispatch served on the nearest warm
larger bucket equals padding to that bucket explicitly, and a bucketed
scheduler generates exactly the tokens of the fixed-shape scheduler.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro
from repro.api.cache import ExecutableCache, prune
from repro.api.options import CompileOptions
from repro.core import ModelBuilder
from repro.runtime import Bucket, BucketPolicy, EngineCache, powers_of_two
from repro.runtime.bucketed import BucketedExecutable

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mlp():
    mb = ModelBuilder().seed(0)
    x = mb.input((16,))
    h = mb.dense(x, 32, activation="relu")
    out = mb.build([mb.dense(h, 8)])
    return out


def _out(d):
    """The single output array of an executable call."""
    return np.asarray(next(iter(d.values())))


class TickClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


# ---------------------------------------------------------------------------
# BucketPolicy: pure arithmetic
# ---------------------------------------------------------------------------
def test_powers_of_two_always_includes_hi():
    assert powers_of_two(1, 8) == (1, 2, 4, 8)
    assert powers_of_two(1, 6) == (1, 2, 4, 6)
    assert powers_of_two(3, 3) == (3,)
    with pytest.raises(ValueError):
        powers_of_two(4, 2)


def test_bucket_for_batch_one_and_exact_boundaries():
    pol = BucketPolicy(batch_buckets=(1, 2, 4))
    assert pol.bucket_for(1) == Bucket(1)
    assert pol.bucket_for(2) == Bucket(2)      # boundary: no round-up
    assert pol.bucket_for(3) == Bucket(4)
    assert pol.bucket_for(4) == Bucket(4)


def test_bucket_for_above_largest_is_exact_overflow():
    pol = BucketPolicy(batch_buckets=(1, 2, 4))
    b = pol.bucket_for(7)
    assert b == Bucket(7)
    assert not pol.covers(b)
    assert pol.covers(Bucket(2))


def test_bucket_for_lengths():
    pol = BucketPolicy(batch_buckets=(1, 4), len_buckets=(8, 32))
    assert pol.bucket_for(1, 5) == Bucket(1, 8)
    assert pol.bucket_for(1, 8) == Bucket(1, 8)       # boundary
    assert pol.bucket_for(3, 9) == Bucket(4, 32)
    assert pol.bucket_for(1, 40) == Bucket(1, 40)     # length overflow
    # no length buckets -> lengths are ignored entirely
    assert BucketPolicy(batch_buckets=(2,)).bucket_for(1, 99) == Bucket(2)


def test_policy_validation_and_round_trip():
    with pytest.raises(ValueError):
        BucketPolicy(batch_buckets=())
    with pytest.raises(ValueError):
        BucketPolicy(batch_buckets=(0, 2))
    with pytest.raises(ValueError):
        BucketPolicy(batch_buckets=(2,), len_buckets=(-8,))
    pol = BucketPolicy(batch_buckets=(4, 1, 2, 2))   # dedup + sort
    assert pol.batch_buckets == (1, 2, 4)
    assert BucketPolicy.from_dict(pol.to_dict()) == pol


def test_enumerate_and_clip():
    pol = BucketPolicy(batch_buckets=(1, 2, 8), len_buckets=(16, 64))
    assert pol.enumerate_buckets() == (
        Bucket(1, 16), Bucket(1, 64), Bucket(2, 16), Bucket(2, 64),
        Bucket(8, 16), Bucket(8, 64))
    clipped = pol.clip(max_batch=4, max_len=48)
    assert clipped.batch_buckets == (1, 2, 4)
    assert clipped.len_buckets == (16, 48)


def test_pad_waste_accounting():
    assert BucketPolicy.pad_waste(3, None, Bucket(4)) == pytest.approx(0.25)
    assert BucketPolicy.pad_waste(4, None, Bucket(4)) == 0.0
    assert BucketPolicy.pad_waste(1, 10, Bucket(2, 16)) == pytest.approx(
        1.0 - 10 / 32)


# ---------------------------------------------------------------------------
# EngineCache: hit / miss+fallback / stall state machine
# ---------------------------------------------------------------------------
def test_engine_cache_miss_falls_back_then_swaps_in():
    pol = BucketPolicy(batch_buckets=(1, 2, 4))
    clock = TickClock()
    cache = EngineCache(pol, build=lambda b: ("prog", b), worker="manual",
                        clock=clock)
    cache.put(Bucket(4), ("prog", Bucket(4)))

    entry, bucket, exact = cache.get(2)       # cold b2: nearest warm is b4
    assert bucket == Bucket(4) and not exact
    assert entry == ("prog", Bucket(4))
    s = cache.stats()
    assert (s["bucket_misses"], s["fallback_serves"],
            s["compile_stalls"]) == (1, 1, 0)

    assert cache.drain() == 1                 # background compile lands
    s = cache.stats()
    assert s["background_compiles"] == 1
    assert s["compile_ms"] > 0                # fake clock ticked
    entry, bucket, exact = cache.get(2)       # now an exact hit
    assert bucket == Bucket(2) and exact
    assert cache.stats()["bucket_hits"] == 1


def test_engine_cache_stall_when_nothing_covers():
    pol = BucketPolicy(batch_buckets=(1, 4))
    cache = EngineCache(pol, build=lambda b: b.batch, worker="manual")
    entry, bucket, exact = cache.get(3)       # empty cache: must stall
    assert entry == 4 and bucket == Bucket(4) and exact
    assert cache.stats()["compile_stalls"] == 1
    assert cache.get(3)[0] == 4               # warm now
    assert cache.stats()["compile_stalls"] == 1


def test_engine_cache_fallback_never_uses_smaller_bucket():
    pol = BucketPolicy(batch_buckets=(1, 2, 4))
    cache = EngineCache(pol, build=lambda b: b, worker="manual")
    cache.put(Bucket(1), Bucket(1))
    _, bucket, exact = cache.get(2)           # b1 warm but too small
    assert bucket == Bucket(2) and exact      # stall-compiled, not b1
    assert cache.stats()["compile_stalls"] == 1


def test_engine_cache_build_failure_surfaces_and_allows_retry():
    calls = []

    def build(b):
        calls.append(b)
        if len(calls) < 3:
            raise RuntimeError("flaky toolchain")
        return "ok"

    cache = EngineCache(BucketPolicy(batch_buckets=(2,)), build,
                        worker="manual")
    with pytest.raises(RuntimeError):
        cache.get(2)
    assert cache.get(2)[0] == "ok"            # in-flight mark was dropped


def test_engine_cache_warm_up_blocking_and_stats_keys():
    pol = BucketPolicy(batch_buckets=(1, 2))
    cache = EngineCache(pol, build=lambda b: b, worker="manual")
    cache.warm_up(block=True)
    assert cache.warm_buckets() == (Bucket(1), Bucket(2))
    assert cache.wait_warm(timeout=1.0)
    s = cache.stats()
    for key in ("bucket_hits", "bucket_misses", "fallback_serves",
                "background_compiles", "compile_stalls", "compile_ms",
                "warm_buckets", "pad_elems", "total_elems",
                "pad_waste_frac"):
        assert key in s


# ---------------------------------------------------------------------------
# BucketedExecutable: dispatch equivalences
# ---------------------------------------------------------------------------
def test_bucketed_fallback_bit_identical_to_explicit_padding(rng):
    g = _mlp()
    x = rng.standard_normal((2, 16)).astype(np.float32)

    exact = repro.compile(_mlp(), CompileOptions(target="jit"))
    want = _out(exact(input=x))
    padded = np.zeros((4, 16), np.float32)
    padded[:2] = x
    want_via_b4 = _out(exact(input=padded))[:2]
    np.testing.assert_array_equal(want, want_via_b4)

    inner = repro.compile(g, CompileOptions(target="jit"))
    exe = BucketedExecutable(inner, BucketPolicy(batch_buckets=(1, 2, 4)),
                             worker="manual")
    exe.ensure_compiled(4)                    # only b4 is warm
    got = _out(exe(input=x))        # b2 cold: served on b4
    s = exe.runtime_stats()
    assert s["fallback_serves"] == 1 and s["warm_buckets"] == ["b4"]
    np.testing.assert_array_equal(want, got)

    exe._cache.drain()                        # b2 swaps in
    got2 = _out(exe(input=x))
    s = exe.runtime_stats()
    assert s["bucket_hits"] == 1 and "b2" in s["warm_buckets"]
    np.testing.assert_array_equal(want, got2)
    exe.shutdown()


def test_bucketed_overflow_batch_compiles_exact(rng):
    exe = repro.compile(_mlp(), CompileOptions(
        target="jit", buckets=BucketPolicy(batch_buckets=(1, 2))))
    x = rng.standard_normal((5, 16)).astype(np.float32)
    out = _out(exe(input=x))        # above largest bucket
    assert out.shape == (5, 8)
    assert "b5" in exe.runtime_stats()["warm_buckets"]
    want = _out(repro.compile(_mlp(), CompileOptions(target="jit"))(
        input=x))
    np.testing.assert_array_equal(want, out)
    exe.shutdown()


def test_compile_options_buckets_validation():
    with pytest.raises(ValueError):
        CompileOptions(buckets=BucketPolicy(batch_buckets=(1, 2)),
                       batch_buckets=(1, 2))      # mutually exclusive
    with pytest.raises(ValueError):
        CompileOptions(buckets="b4")
    with pytest.raises(TypeError):
        repro.compile(_mlp(), CompileOptions(
            target="interpret", buckets=BucketPolicy(batch_buckets=(1,))))
    with pytest.raises(ValueError):               # serving-only knob
        BucketedExecutable(
            repro.compile(_mlp(), CompileOptions(target="jit")),
            BucketPolicy(batch_buckets=(1,), len_buckets=(8,)))


def test_bucketed_serialize_round_trip(rng):
    pol = BucketPolicy(batch_buckets=(1, 2))
    exe = repro.compile(_mlp(), CompileOptions(target="jit", buckets=pol))
    x = rng.standard_normal((2, 16)).astype(np.float32)
    want = _out(exe(input=x))
    blob = exe.serialize()
    exe2 = repro.deserialize(blob)
    assert isinstance(exe2, BucketedExecutable)
    assert exe2.policy == pol
    np.testing.assert_array_equal(want, _out(exe2(input=x)))
    exe.shutdown()
    exe2.shutdown()


def test_cross_process_prewarm_zero_compiles(tmp_path):
    """Process 1 compiles every bucket into the persistent cache;
    process 2 constructs the same bucketed executable and starts with
    every bucket warm — N disk hits, zero compiles, zero stalls."""
    prog = """
import json, sys
sys.path.insert(0, {src!r})
import numpy as np
import repro
from repro.api.options import CompileOptions
from repro.core import ModelBuilder
from repro.runtime import BucketPolicy
mb = ModelBuilder().seed(0)
x = mb.input((16,))
h = mb.dense(x, 32, activation="relu")
g = mb.build([mb.dense(h, 8)])
exe = repro.compile(g, CompileOptions(
    target="jit", cache_dir={cache!r},
    buckets=BucketPolicy(batch_buckets=(1, 2, 4))))
exe.warm_up(block=True)
out = list(exe(input=np.ones((3, 16), np.float32)).values())[0]
stats = exe.runtime_stats()
print(json.dumps({{"disk": exe.cache_info(), "warm": stats["warm_buckets"],
                   "stalls": stats["compile_stalls"],
                   "out": np.asarray(out).tolist()}}))
"""
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    reports = []
    for _ in range(2):
        out = subprocess.run(
            [sys.executable, "-c",
             prog.format(src=os.path.join(REPO, "src"),
                         cache=str(tmp_path))],
            capture_output=True, text=True, env=env, check=True)
        reports.append(json.loads(out.stdout.strip().splitlines()[-1]))
    first, second = reports
    assert first["disk"]["misses"] == 3       # three buckets compiled
    assert first["warm"] == ["b1", "b2", "b4"]
    # second process: pre-warmed entirely from disk at construction
    assert second["disk"]["hits"] == 3
    assert second["disk"]["misses"] == 0
    assert second["warm"] == ["b1", "b2", "b4"]
    assert second["stalls"] == 0
    assert first["out"] == second["out"]      # and bit-identical outputs


# ---------------------------------------------------------------------------
# Autotune interop: tactic keys are per-bucket, and both hit on re-run
# ---------------------------------------------------------------------------
def test_tactic_keys_distinct_per_bucket_and_hit_on_rerun(tmp_path):
    pol = BucketPolicy(batch_buckets=(1, 2))
    opts = CompileOptions(target="pallas", autotune="full",
                          autotune_budget_ms=20_000,
                          cache_dir=str(tmp_path), buckets=pol)
    exe = repro.compile(_mlp(), opts)
    exe.warm_up(block=True)
    reports = exe.inner.cost_summary()["autotune"]
    assert set(reports) == {1, 2}
    # the buckets' problem shapes differ (m = batch), so their tactic
    # keys differ — each bucket measured its own tactics
    assert reports[1]["measured_nodes"] == ["dense_1", "dense_3"]
    assert reports[2]["measured_nodes"] == ["dense_1", "dense_3"]
    exe.shutdown()

    exe2 = repro.compile(_mlp(), opts)        # fresh executable, same caches
    exe2.warm_up(block=True)
    reports = exe2.inner.cost_summary()["autotune"]
    for batch in (1, 2):
        assert reports[batch]["measured_nodes"] == []      # no re-measure
        assert set(reports[batch]["cached_nodes"]) == {"dense_1", "dense_3"}
    exe2.shutdown()


# ---------------------------------------------------------------------------
# Cache hygiene: prune / REPRO_CACHE_MAX_BYTES
# ---------------------------------------------------------------------------
def test_prune_lru_sweep_and_tmp_cleanup(tmp_path):
    for i in range(5):
        p = tmp_path / f"e{i}.xla"
        p.write_bytes(b"x" * 100)
        os.utime(p, (i + 1, i + 1))           # e0 oldest ... e4 newest
    (tmp_path / "orphan.tmp").write_bytes(b"partial")
    (tmp_path / "notes.txt").write_bytes(b"keep me")

    rep = prune(250, str(tmp_path))
    assert rep["before_bytes"] == 500
    assert rep["after_bytes"] == 200          # two newest survive
    assert rep["removed"] == 4                # three .xla + the .tmp
    left = sorted(p.name for p in tmp_path.iterdir())
    assert left == ["e3.xla", "e4.xla", "notes.txt"]

    assert prune(0, str(tmp_path))["after_bytes"] == 0
    with pytest.raises(ValueError):
        prune(-1, str(tmp_path))
    # missing / disabled dirs are a clean no-op
    assert prune(10, str(tmp_path / "nope"))["removed"] == 0


def test_prune_sharded_manifest_is_one_atomic_group(tmp_path):
    """A sharded executable's per-batch artifacts + manifest are one LRU
    unit: recency is the hottest member, eviction takes the whole group,
    and a dangling manifest is cleaned up front."""
    import json

    # Group of two cold members (mtimes 1 and 5) under one manifest.
    for i, key in enumerate(("s1", "s2")):
        p = tmp_path / f"{key}.xla"
        p.write_bytes(b"x" * 100)
        os.utime(p, (1 + 4 * i, 1 + 4 * i))
    man = tmp_path / "g.manifest.json"
    man.write_text(json.dumps({"mesh": {"axes": [["data", 1]]},
                               "members": ["s1", "s2"]}))
    # A loose entry colder than the group's hottest member (mtime 3):
    # evicted first even though member s2 (mtime 5) is hotter than it.
    loose = tmp_path / "loose.xla"
    loose.write_bytes(b"x" * 100)
    os.utime(loose, (3, 3))
    group_bytes = 200 + man.stat().st_size

    rep = prune(group_bytes, str(tmp_path))
    assert rep["removed"] == 1                      # just the loose entry
    left = sorted(p.name for p in tmp_path.iterdir())
    assert left == ["g.manifest.json", "s1.xla", "s2.xla"]

    # Shrinking below the group size removes members AND manifest —
    # never a manifest pointing at missing artifacts.
    rep = prune(50, str(tmp_path))
    assert rep["after_bytes"] == 0
    assert list(tmp_path.iterdir()) == []

    # Dangling manifest (members already gone) is swept up front.
    man.write_text(json.dumps({"members": ["gone"]}))
    assert prune(10_000, str(tmp_path))["removed"] == 1
    assert list(tmp_path.iterdir()) == []


def test_store_auto_prunes_under_env_cap(tmp_path, monkeypatch):
    def compiled(i):
        fn = jax.jit(lambda x: x + i)
        return fn.lower(
            jax.ShapeDtypeStruct((4,), jnp.float32)).compile()

    cache = ExecutableCache(str(tmp_path))
    if not cache.store("k0", compiled(0)):
        pytest.skip("executable serialization unavailable on this jax")
    assert cache.store("k1", compiled(1))
    os.utime(tmp_path / "k0.xla", (1, 1))     # k0 is the LRU entry
    os.utime(tmp_path / "k1.xla", (2, 2))
    cap = (os.path.getsize(tmp_path / "k0.xla")
           + os.path.getsize(tmp_path / "k1.xla"))
    monkeypatch.setenv("REPRO_CACHE_MAX_BYTES", str(cap))
    assert cache.store("k2", compiled(2))     # overflows: sweep runs
    left = sorted(p.name for p in tmp_path.glob("*.xla"))
    assert "k0.xla" not in left               # oldest evicted first
    assert "k2.xla" in left                   # the fresh store survives
    assert sum(os.path.getsize(tmp_path / n) for n in left) <= cap


# ---------------------------------------------------------------------------
# Shape-polymorphic serving
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def serve_setup():
    from repro.configs import get_config
    from repro.models import get_model
    cfg = get_config("qwen2.5-14b", smoke=True)
    m = get_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def test_scheduler_options_buckets_validation():
    from repro.serve import SchedulerOptions
    pol = BucketPolicy(batch_buckets=(1, 2))
    opts = SchedulerOptions(buckets=pol)
    assert SchedulerOptions(buckets=opts.to_dict()["buckets"]).buckets == pol
    with pytest.raises(ValueError):
        SchedulerOptions(buckets=(1, 2))


def test_slot_compaction_moves_highest_active_into_hole():
    from repro.serve.slots import SlotManager, SlotState

    class FakeModel:
        def init_cache(self, b, max_len):
            return {"kv": jnp.zeros((2, b, max_len)),
                    "pos": jnp.zeros((b,), jnp.int32)}

    sm = SlotManager(FakeModel(), slots=4, max_len=8)
    for slot, uid in ((0, 10), (1, 11), (2, 12)):
        one = {"kv": jnp.full((2, 1, 8), float(uid)),
               "pos": jnp.full((1,), uid, jnp.int32)}
        sm.admit(slot, SlotState(uid=uid, remaining=4, eos_id=-1,
                                 temperature=0.0), one)
    assert sm.compact() == []                 # already a prefix
    sm.evict(0)
    assert sm.compact() == [(2, 0)]           # highest active fills hole
    assert [st.uid if st else None for st in sm._states] == \
        [12, 11, None, None]
    assert float(sm.cache["kv"][0, 0, 0]) == 12.0
    assert int(sm.cache["pos"][0]) == 12


def test_bucketed_serving_tokens_identical_to_fixed(serve_setup):
    from repro.serve import Request, Scheduler, SchedulerOptions
    cfg, m, params = serve_setup
    rng = np.random.RandomState(3)
    prompts = [rng.randint(1, 50, size=(l,)).astype(np.int32)
               for l in (3, 9, 17, 5, 21, 12)]

    def run(buckets):
        kw = {"engine_worker": "manual"} if buckets is not None else {}
        s = Scheduler(m, params, SchedulerOptions(
            slots=3, max_len=32, fold=False, buckets=buckets), **kw)
        for i, p in enumerate(prompts):
            s.submit(Request(uid=i, prompt=p, max_new_tokens=4))
        done = s.run()
        toks = {c.uid: c.tokens for c in done}
        summ = s.summary()
        s.shutdown()
        return toks, summ

    base, base_summ = run(None)
    assert "runtime" not in base_summ          # fixed-shape: no new keys
    pol = BucketPolicy.default(max_batch=3, max_len=32, min_len=8)
    buck, summ = run(pol)
    assert buck == base                        # greedy tokens, bit-equal
    rt = summ["runtime"]
    assert rt["bucket_hits"] > 0
    assert rt["pad_waste_frac"] > 0            # mixed lengths did pad
    assert set(rt["decode"]) >= {"bucket_hits", "warm_buckets"}
    assert set(rt["prefill"]) >= {"bucket_hits", "warm_buckets"}
    # the full-slots decode program is warmed synchronously at build,
    # so the decode path can never stall
    assert rt["decode"]["compile_stalls"] == 0


def test_bucketed_scheduler_steady_state_no_stalls(serve_setup):
    from repro.serve import Request, Scheduler, SchedulerOptions
    cfg, m, params = serve_setup
    clock = TickClock()
    pol = BucketPolicy(batch_buckets=(1, 2), len_buckets=(8, 32))
    s = Scheduler(m, params, SchedulerOptions(
        slots=2, max_len=32, fold=False, buckets=pol),
        engine_worker="manual", clock=clock)
    rng = np.random.RandomState(0)

    s.submit(Request(uid=0, prompt=rng.randint(1, 50, size=(5,)),
                     max_new_tokens=3))
    s.run()
    first = s.summary()["runtime"]
    # cold prefill bucket: the one allowed stall, drained inline in
    # manual mode (which also lands the queued background compiles)
    assert first["compile_stalls"] == 1
    assert first["background_compiles"] > 0
    assert s.wait_warm(timeout=5.0)

    for uid, plen in ((1, 4), (2, 7), (3, 20), (4, 30)):
        s.submit(Request(uid=uid, prompt=rng.randint(1, 50, size=(plen,)),
                         max_new_tokens=3))
    s.run()
    steady = s.summary()["runtime"]
    assert steady["compile_stalls"] == first["compile_stalls"]  # zero new
    assert steady["bucket_hits"] > first["bucket_hits"]
    s.shutdown()


def test_ring_cache_models_disable_length_buckets(serve_setup):
    """All-sliding-window models allocate a ring cache shorter than
    max_len; padded prefill would roll real tokens out, so length
    bucketing must switch itself off (batch bucketing stays on)."""
    import dataclasses
    from repro.models import get_model
    from repro.serve import Scheduler, SchedulerOptions
    cfg, _, _ = serve_setup
    ring_cfg = dataclasses.replace(cfg, pattern="swa", window=8)
    m = get_model(ring_cfg)
    params = m.init(jax.random.PRNGKey(0))
    pol = BucketPolicy(batch_buckets=(1, 2), len_buckets=(8, 16))
    s = Scheduler(m, params, SchedulerOptions(
        slots=2, max_len=32, fold=False, buckets=pol),
        engine_worker="manual")
    assert s._decode_engine is not None
    assert s._prefill_engine is None
    s.shutdown()
