"""Calibration-driven quantization: the quantize pass, the
dtype-specialized kernels, and the low-precision compile surface.

Covers the PR's acceptance contract: per-precision golden identity
across interpret/jit/pallas, ``precision="f32"`` bit-identity with the
exact pipeline, deterministic calibration under the fixed seed,
``quant.*`` attrs surviving the container round trip, and a subprocess
persistent-cache round trip with zero recompiles."""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import repro
from repro.api import CompileOptions
from repro.core import ModelBuilder
from repro.core.passes import run_pipeline
from repro.kernels.tiles import block_vmem_bytes


def _mlp():
    mb = ModelBuilder().seed(7)
    x = mb.input((20,))
    h = mb.dense(x, 64, activation="tanh")
    h = mb.dense(h, 48, activation="relu")
    h = mb.dense(h, 32, activation="tanh")
    out = mb.dense(h, 9)
    return mb.build([out]), out


def _cnn():
    mb = ModelBuilder().seed(8)
    x = mb.input((10, 10, 3))
    h = mb.conv2d(x, 8, (3, 3), activation="relu")
    h = mb.batchnorm(h)
    h = mb.global_avg_pool(h)
    out = mb.dense(h, 5)
    return mb.build([out]), out


# ---------------------------------------------------------------------------
# Satellite: the VMEM model's accumulator itemsize
# ---------------------------------------------------------------------------
def test_block_vmem_bytes_itemsize_geometry():
    """Operand bytes scale with itemsize; acc/out bytes with
    acc_itemsize — f32 (4), bf16 (2), and int8 (1) tiles of the same
    block differ exactly by the operand-byte term."""
    bm, bk, bn = 128, 512, 128
    operands = bm * bk + bk * bn
    acc = 2 * bm * bn
    for itemsize in (1, 2, 4):
        got = block_vmem_bytes(bm, bk, bn, itemsize)
        assert got == itemsize * operands + 4 * acc
    # the int8 kernel budgets an i32 scratch + f32 out: acc_itemsize=4
    # is the default, but the parameter must be honored when it is not
    assert block_vmem_bytes(bm, bk, bn, 1, acc_itemsize=8) == \
        operands + 8 * acc


# ---------------------------------------------------------------------------
# Calibration determinism
# ---------------------------------------------------------------------------
def _quantized_graph(graph, mode="int8", calibrate=4):
    g = graph.copy()
    g.quant = {"mode": mode, "calibrate": calibrate, "measure": False}
    out, _ = run_pipeline(g, ("quantize",))
    return out


def test_calibration_ranges_deterministic():
    g, _ = _mlp()
    a = _quantized_graph(g)
    b = _quantized_graph(g)
    sites = [n for n in a.nodes if "quant.x_scale" in n.attrs]
    assert sites, "int8 mode must annotate dense sites"
    for na, nb in zip(a.nodes, b.nodes):
        assert na.attrs.get("quant.x_scale") == nb.attrs.get("quant.x_scale")
        assert na.attrs.get("quant.w_scale") == nb.attrs.get("quant.w_scale")
    assert a.structure_hash() == b.structure_hash()


def test_quant_attrs_flow_into_structure_hash():
    g, _ = _mlp()
    assert _quantized_graph(g).structure_hash() != \
        _quantized_graph(g, mode="bf16").structure_hash()


# ---------------------------------------------------------------------------
# Golden identity per precision
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("graph_fn", [_mlp, _cnn], ids=["mlp", "cnn"])
@pytest.mark.parametrize("prec", ["f32", "bf16", "int8"])
def test_golden_identity_across_targets(graph_fn, prec, rng):
    g, out = graph_fn()
    x = rng.standard_normal((4,) + next(iter(g.inputs.values())).shape) \
        .astype(np.float32)
    outs = {}
    for tgt in ("interpret", "jit", "pallas"):
        exe = repro.compile(g, CompileOptions(target=tgt, precision=prec))
        outs[tgt] = np.asarray(exe(input=x)[out])
    # jit and pallas trace the same annotated graph through the same
    # shared quant expressions; int8's i32 accumulation is exact under
    # any blocking, so these two are bit-identical.
    np.testing.assert_array_equal(outs["jit"], outs["pallas"])
    # the eager oracle differs only by XLA's jit-side fma contraction
    # of the dequant/bias chain (~1 ulp of the activations)
    np.testing.assert_allclose(outs["interpret"], outs["jit"], atol=1e-5)


def test_f32_bit_identical_to_exact_pipeline(rng):
    """precision='f32' must be today's pipeline exactly — same graph,
    same kernels, bit-identical outputs on both compiled targets."""
    g, out = _cnn()
    x = rng.standard_normal((2, 10, 10, 3)).astype(np.float32)
    for tgt in ("jit", "pallas"):
        exact = repro.compile(g, CompileOptions(target=tgt))
        f32 = repro.compile(g, CompileOptions(target=tgt, precision="f32"))
        np.testing.assert_array_equal(
            np.asarray(exact(input=x)[out]), np.asarray(f32(input=x)[out]))
        assert f32.cost_summary().get("quant") is None


def test_int8_error_within_default_budget(rng):
    g, out = _mlp()
    x = rng.standard_normal((4, 20)).astype(np.float32)
    want = np.asarray(repro.compile(g, CompileOptions())(input=x)[out])
    got = np.asarray(repro.compile(
        g, CompileOptions(precision="int8"))(input=x)[out])
    assert float(np.abs(want - got).max()) <= 0.05


def test_backend_prior_conv_stays_f32_off_tpu():
    """Off-TPU, int8 annotates dense sites only (XLA CPU int8 conv is a
    slowdown); bf16 annotates both."""
    import jax
    if any(d.platform == "tpu" for d in jax.devices()):
        pytest.skip("prior under test is the CPU one")
    g, _ = _cnn()
    q8 = _quantized_graph(g, mode="int8")
    modes8 = {n.op: n.attrs.get("quant.mode") for n in q8.nodes
              if n.op in ("dense", "conv2d")}
    assert modes8["dense"] == "int8" and modes8["conv2d"] is None
    qb = _quantized_graph(g, mode="bf16")
    modesb = {n.op: n.attrs.get("quant.mode") for n in qb.nodes
              if n.op in ("dense", "conv2d")}
    assert modesb == {"dense": "bf16", "conv2d": "bf16"}


# ---------------------------------------------------------------------------
# Options surface
# ---------------------------------------------------------------------------
def test_quant_options_validation():
    with pytest.raises(ValueError):
        CompileOptions(calibrate=0)
    with pytest.raises(ValueError):
        CompileOptions(calibrate=-3)
    with pytest.raises(ValueError):
        CompileOptions(precision_budget=0.0)
    CompileOptions(precision="int8", calibrate=2, precision_budget=0.1)


def test_cost_summary_reports_decisions(rng):
    g, _ = _mlp()
    exe = repro.compile(g, CompileOptions(precision="int8"))
    q = exe.cost_summary()["quant"]
    assert q["mode"] == "int8"
    assert q["decisions"]["int8"] == 4


# ---------------------------------------------------------------------------
# Serialization + persistent cache
# ---------------------------------------------------------------------------
def test_scale_attrs_survive_container_roundtrip(tmp_path):
    from repro.frontends.container import load_model, save_model
    g, _ = _mlp()
    q = _quantized_graph(g)
    path = tmp_path / "quantized.npz"
    save_model(q, str(path))
    r = load_model(str(path))
    for a, b in zip(q.nodes, r.nodes):
        for key in ("quant.mode", "quant.x_scale", "quant.w_scale",
                    "quant.zp"):
            assert a.attrs.get(key) == b.attrs.get(key), (a.name, key)
    assert q.structure_hash() == r.structure_hash()


def test_serialized_executable_reproduces_quantized_outputs(rng):
    g, out = _mlp()
    x = rng.standard_normal((2, 20)).astype(np.float32)
    exe = repro.compile(g, CompileOptions(precision="int8"))
    want = np.asarray(exe(input=x)[out])
    clone = repro.deserialize(exe.serialize())
    np.testing.assert_array_equal(want, np.asarray(clone(input=x)[out]))


_SUBPROC = textwrap.dedent("""
    import json, sys
    import numpy as np
    import repro
    from repro.api import CompileOptions
    sys.path.insert(0, {test_dir!r})
    from test_quantize import _mlp
    g, out = _mlp()
    x = np.linspace(-1, 1, 40, dtype=np.float32).reshape(2, 20)
    exe = repro.compile(g, CompileOptions(precision="int8",
                                          calibrate=3,
                                          cache_dir={cache!r}))
    y = exe(input=x)[out]
    print(json.dumps({{"cache": exe.cache_info(),
                       "digest": float(np.asarray(y).sum())}}))
""")


def test_quant_cache_subprocess_zero_recompiles(tmp_path):
    """Two processes, same int8 compile, shared cache dir: the second
    must serve the executable from disk (0 recompiles) and produce the
    same output — deterministic calibration is what keeps the key
    stable across processes."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _SUBPROC.format(test_dir=os.path.dirname(__file__),
                             cache=str(tmp_path))
    reports = []
    for _ in range(2):
        out = subprocess.run([sys.executable, "-c", script], env=env,
                             capture_output=True, text=True, timeout=600)
        assert out.returncode == 0, out.stderr
        reports.append(json.loads(out.stdout.strip().splitlines()[-1]))
    assert reports[0]["cache"]["misses"] == 1
    assert reports[1]["cache"]["misses"] == 0, "second process recompiled"
    assert reports[1]["cache"]["hits"] == 1
    assert reports[0]["digest"] == reports[1]["digest"]


def test_precision_changes_cache_key(tmp_path, rng):
    g, _ = _mlp()
    x = rng.standard_normal((2, 20)).astype(np.float32)
    e1 = repro.compile(g, CompileOptions(cache_dir=str(tmp_path)))
    e1(input=x)
    e2 = repro.compile(g, CompileOptions(cache_dir=str(tmp_path),
                                         precision="int8"))
    e2(input=x)
    assert e2.cache_info()["misses"] == 1 and e2.cache_info()["hits"] == 0
    e3 = repro.compile(g, CompileOptions(cache_dir=str(tmp_path),
                                         precision="int8", calibrate=8))
    e3(input=x)
    assert e3.cache_info()["misses"] == 1, \
        "calibrate must be part of the compile cache key"


# ---------------------------------------------------------------------------
# Serving surface
# ---------------------------------------------------------------------------
def test_serve_summary_reports_precision():
    """The engine target serves weight-only bf16 (rejecting graph-routed
    int8), and the scheduler's summary() carries the precision audit
    record through from the compiled executable."""
    from repro.configs import get_config
    cfg = get_config("qwen2.5-14b", smoke=True)
    with pytest.raises(ValueError, match="engine"):
        repro.compile(cfg, CompileOptions(target="engine",
                                          precision="int8"))
    exe = repro.compile(cfg, CompileOptions(target="engine",
                                            precision="bf16"))
    q = exe.cost_summary()["quant"]
    assert q["mode"] == "bf16" and q["decisions"]["bf16"] > 0
    import repro as _r
    sched = _r.serve(exe, _r.SchedulerOptions(slots=2, max_len=32))
    try:
        prec = sched.summary()["precision"]
        assert prec["precision"] == "bf16"
        assert prec["decisions"] == q["decisions"]
    finally:
        sched.shutdown()


# ---------------------------------------------------------------------------
# Mixed mode
# ---------------------------------------------------------------------------
def test_mixed_mode_measures_and_respects_budget(tmp_path, rng):
    g, out = _mlp()
    x = rng.standard_normal((2, 20)).astype(np.float32)
    exe = repro.compile(g, CompileOptions(
        precision="mixed", precision_budget=1e-9, cache_dir=str(tmp_path)))
    q = exe.cost_summary()["quant"]
    assert q["mode"] == "mixed"
    # a budget this tight rejects every narrow candidate: all sites f32,
    # and the output is exactly the f32 program's
    assert q["decisions"]["f32"] == 4
    want = np.asarray(repro.compile(g, CompileOptions())(input=x)[out])
    np.testing.assert_array_equal(want, np.asarray(exe(input=x)[out]))
