"""Sharding rules + a real multi-device SPMD check in a subprocess
(8 forced host devices — the main pytest process keeps the 1 real
device, per the assignment)."""

import subprocess
import sys
import textwrap

import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd


def test_spec_for_dedups_mesh_axes():
    spec = shd.spec_for("batch", "seq", "heads")
    assert spec == P(("pod", "data"), None, "model")
    # "model" may appear once: kv_heads after heads degrades to None
    spec = shd.spec_for("heads", "kv_heads")
    assert spec == P("model", None)


def test_logical_noop_without_mesh():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    y = shd.logical(x, "batch", "embed")
    assert (x == y).all()


def test_divisible_sharding_fallback():
    from repro.launch.cells import _divisible_sharding
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = jax.ShapeDtypeStruct((7, 16), "float32")
    with shd.use_mesh(mesh):
        s = _divisible_sharding(mesh, spec, ("vocab", "fsdp"))
    # axes of size 1 are dropped entirely (no >1 divisor)
    assert s.spec == P(None, None)


_SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import get_model
    from repro.training import OptConfig, TrainConfig, init_state, \\
        make_jitted_train_step
    from repro.distributed import sharding as shd

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_config("{arch}", smoke=True)
    m = get_model(cfg)
    tc = TrainConfig(opt=OptConfig(lr=1e-3, total_steps=4,
                                   warmup_steps=0), microbatches=2)
    with shd.use_mesh(mesh):
        state = init_state(m, jax.random.PRNGKey(0))
        step = make_jitted_train_step(m, tc, mesh=mesh, donate=False)
        batch = {{
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32),
                                         0, cfg.vocab),
            "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32),
                                         0, cfg.vocab),
        }}
        losses = []
        for _ in range(3):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
    assert all(l == l for l in losses), losses     # no NaN
    assert losses[-1] < losses[0], losses          # learns the batch
    print("SPMD_OK", losses)
""")


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "mixtral-8x22b"])
def test_spmd_train_step_8dev(arch):
    """Real SPMD execution on 8 host devices: the same model code +
    sharding rules as the production mesh, shrunk to (2, 4)."""
    out = subprocess.run(
        [sys.executable, "-c", _SPMD_SCRIPT.format(arch=arch)],
        capture_output=True, text=True, cwd=".", timeout=900)
    assert "SPMD_OK" in out.stdout, out.stdout + out.stderr
