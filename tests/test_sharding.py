"""Sharding rules + a real multi-device SPMD check in a subprocess
(8 forced host devices — the main pytest process keeps the 1 real
device, per the assignment), plus the mesh-aware-compile golden tests:
propagation placement, single-device bit-identity over the Table-1
suite, 2×2 data×model serve token identity, and the cross-process
serialize/deserialize warm-cache round-trip."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec as P

import repro
from repro.api import CompileOptions
from repro.core import ModelBuilder
from repro.distributed import sharding as shd
from repro.dist.mesh import MeshSpec


def test_spec_for_dedups_mesh_axes():
    spec = shd.spec_for("batch", "seq", "heads")
    assert spec == P(("pod", "data"), None, "model")
    # "model" may appear once: kv_heads after heads degrades to None
    spec = shd.spec_for("heads", "kv_heads")
    assert spec == P("model", None)


def test_logical_noop_without_mesh():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    y = shd.logical(x, "batch", "embed")
    assert (x == y).all()


def test_divisible_sharding_fallback():
    from repro.launch.cells import _divisible_sharding
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = jax.ShapeDtypeStruct((7, 16), "float32")
    with shd.use_mesh(mesh):
        s = _divisible_sharding(mesh, spec, ("vocab", "fsdp"))
    # axes of size 1 are dropped entirely (no >1 divisor)
    assert s.spec == P(None, None)


_SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import get_model
    from repro.training import OptConfig, TrainConfig, init_state, \\
        make_jitted_train_step
    from repro.distributed import sharding as shd

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_config("{arch}", smoke=True)
    m = get_model(cfg)
    tc = TrainConfig(opt=OptConfig(lr=1e-3, total_steps=4,
                                   warmup_steps=0), microbatches=2)
    with shd.use_mesh(mesh):
        state = init_state(m, jax.random.PRNGKey(0))
        step = make_jitted_train_step(m, tc, mesh=mesh, donate=False)
        batch = {{
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32),
                                         0, cfg.vocab),
            "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32),
                                         0, cfg.vocab),
        }}
        losses = []
        for _ in range(3):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
    assert all(l == l for l in losses), losses     # no NaN
    assert losses[-1] < losses[0], losses          # learns the batch
    print("SPMD_OK", losses)
""")


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "mixtral-8x22b"])
def test_spmd_train_step_8dev(arch):
    """Real SPMD execution on 8 host devices: the same model code +
    sharding rules as the production mesh, shrunk to (2, 4)."""
    out = subprocess.run(
        [sys.executable, "-c", _SPMD_SCRIPT.format(arch=arch)],
        capture_output=True, text=True, cwd=".", timeout=900)
    assert "SPMD_OK" in out.stdout, out.stdout + out.stderr


# ---------------------------------------------------------------------------
# Mesh-aware compilation (repro.dist): propagation golden placement
# ---------------------------------------------------------------------------
def _mlp_block():
    """A transformer-style MLP block in the graph IR: expand, contract,
    residual — the shape the Megatron column/row split targets."""
    mb = ModelBuilder().seed(7)
    x = mb.input((16,))
    up = mb.dense(x, 32, activation="relu")
    down = mb.dense(up, 16)
    out = mb.add(down, x)
    return mb.build([out]), x, up, down, out


def test_propagation_golden_partition_specs():
    """Under DEFAULT_RULES on data=2,model=2 the MLP block resolves to
    the textbook placement: batch over data everywhere, the expansion
    column-parallel over model, the contraction row-parallel closed by
    exactly one psum, residual and output replicated."""
    from repro.dist.propagate import propagate_shardings

    g, x, up, down, out = _mlp_block()
    g.dist = {"mesh": MeshSpec.parse("data=2,model=2").to_dict(),
              "rules": []}
    stats = propagate_shardings(g)
    assert stats == {"sharded": True, "reused": False, "collectives": 1}

    sh = g.dist["shardings"]
    assert sh[x] == [["data"], None]            # input: batch over data
    assert sh[up] == [["data"], ["model"]]      # column parallel (32 % 2)
    assert sh[down] == [["data"], None]         # row-parallel partial sum
    assert sh[out] == [["data"], None]          # residual: replicated

    psums = [n for n in g.nodes if n.op == "psum"]
    assert len(psums) == 1
    assert psums[0].attrs == {"axis": ["model"], "axis_size": 2}
    assert sh[psums[0].output] == [["data"], None]
    # every later consumer reads the reduced value, not the partial sum
    add = next(n for n in g.nodes if n.op == "add")
    assert psums[0].output in add.inputs and down not in add.inputs
    # ...and the edit log records exactly that placement for replay
    edits = g.dist["edits"]
    assert [e["op"] for e in edits["inserted"]] == ["psum"]
    assert edits["outputs"] == g.outputs


def test_propagation_rule_override_forces_replication():
    """sharding_rules=(("mlp", None),) deletes the tensor-parallel rule:
    no column split, no collectives, batch sharding only."""
    from repro.dist.propagate import propagate_shardings

    g, x, up, down, out = _mlp_block()
    g.dist = {"mesh": MeshSpec.parse("data=2,model=2").to_dict(),
              "rules": [["mlp", None]]}
    stats = propagate_shardings(g)
    assert stats["collectives"] == 0
    assert all(e == [["data"]] + [None] * (len(e) - 1)
               for e in g.dist["shardings"].values())


# ---------------------------------------------------------------------------
# Single-device mesh == unsharded, bit for bit, over the Table-1 suite
# ---------------------------------------------------------------------------
def _table1_suite():
    from benchmarks.table1_models import SUITE
    return SUITE


@pytest.mark.parametrize("name", ["C-HTWK", "C-BH", "Detector",
                                  "Segmenter", "MobileNetV2", "VGG19"])
def test_single_device_mesh_bit_identical(name):
    """CompileOptions(mesh=...) on a 1-device mesh must be bit-identical
    to the unsharded JitExecutable on every Table-1 config — sharding is
    placement, never math."""
    from repro.api.capture import seeded_inputs
    from repro.dist import ShardedExecutable

    g = _table1_suite()[name]()
    inputs = seeded_inputs(g, 1)
    base = repro.compile(g, CompileOptions())(**inputs)
    exe = repro.compile(g, CompileOptions(mesh="data=1,model=1"))
    assert isinstance(exe, ShardedExecutable)
    sharded = exe(**inputs)
    assert sorted(base) == sorted(sharded)
    for k in base:
        np.testing.assert_array_equal(np.asarray(base[k]),
                                      np.asarray(sharded[k]))


# ---------------------------------------------------------------------------
# data×model serve: 2×2 virtual devices, tokens identical to 1 device
# ---------------------------------------------------------------------------
_SERVE_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    import repro
    from repro.configs import get_config
    from repro.serve import Request

    cfg = get_config("qwen2.5-14b", smoke=True)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, int(rng.integers(4, 17)))
               for _ in range(6)]

    def run(mesh):
        exe = repro.compile(cfg, repro.CompileOptions(
            target="engine", mesh=mesh))
        sched = repro.serve(exe, repro.SchedulerOptions(
            slots=4, max_len=64))
        for i, p in enumerate(prompts):
            sched.submit(Request(uid=i, prompt=p, max_new_tokens=8))
        done = sched.run()
        summary = sched.summary()
        sched.shutdown()
        return {c.uid: list(c.tokens) for c in done}, summary

    ref, ref_summary = run(None)
    assert "sharding" not in ref_summary        # unsharded: no mesh block
    got, summary = run("data=2,model=2")
    assert got == ref, (ref, got)

    sh = summary["sharding"]
    assert sh["mesh"] == "data=2,model=2" and sh["devices"] == 4
    assert sh["decode_programs"] >= 1
    # per-axis collective attribution from the post-optimization HLO
    per = sh["collectives"]["per_axis"]
    assert set(per) <= {"data", "model"} and per, per
    assert all(v["count"] >= 1 and v["bytes"] > 0 for v in per.values())
    assert summary["faults"] == []
    print("MESH_TOKENS_OK")
""")


def test_serve_data_model_mesh_tokens_identical_8dev():
    """The acceptance check: a 2×2 data×model serve run on virtual
    devices produces exactly the tokens of the single-device scheduler,
    and summary() gains per-axis collective counts + bytes."""
    out = subprocess.run(
        [sys.executable, "-c", _SERVE_MESH_SCRIPT],
        capture_output=True, text=True, cwd=".", timeout=900)
    assert "MESH_TOKENS_OK" in out.stdout, out.stdout + out.stderr


# ---------------------------------------------------------------------------
# serialize() round-trips mesh + shardings cross-process, warm cache
# ---------------------------------------------------------------------------
_SAVE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    import repro
    from repro.core import ModelBuilder

    mb = ModelBuilder().seed(11)
    x = mb.input((16,))
    h = mb.dense(x, 32, activation="relu")
    h = mb.dense(h, 16)
    g = mb.build([h])

    exe = repro.compile(g, repro.CompileOptions(mesh="data=2,model=2"))
    xs = np.random.default_rng(0).standard_normal((4, 16)).astype("float32")
    out = exe(xs)
    np.save(os.environ["SHARD_REF"], np.asarray(out[list(out)[0]]))
    with open(os.environ["SHARD_ART"], "wb") as f:
        f.write(exe.serialize())
    info = exe.cache_info()
    assert info["misses"] >= 1, info       # cold cache: compiled + stored
    print("SAVE_OK")
""")

_LOAD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    import repro
    from repro.dist import ShardedExecutable

    with open(os.environ["SHARD_ART"], "rb") as f:
        exe = repro.deserialize(f.read())
    assert isinstance(exe, ShardedExecutable)
    assert exe.mesh_spec.describe() == "data=2,model=2"
    # placement was replayed from the manifest, not re-derived
    assert exe.graph.dist["shardings"] and exe.graph.dist["edits"]["inserted"]

    xs = np.random.default_rng(0).standard_normal((4, 16)).astype("float32")
    out = exe(xs)
    ref = np.load(os.environ["SHARD_REF"])
    np.testing.assert_array_equal(np.asarray(out[list(out)[0]]), ref)
    info = exe.cache_info()
    assert info["hits"] >= 1 and info["misses"] == 0, info
    print("LOAD_OK")
""")


def test_sharded_serialize_roundtrip_cross_process(tmp_path):
    """Process A compiles on a 2×2 mesh, executes, serializes; process B
    deserializes and replays the placement with zero re-propagation —
    same cache key, so the warm executable cache hits with 0 recompiles
    (misses == 0) and the outputs match bit for bit."""
    env = {**os.environ,
           "REPRO_CACHE_DIR": str(tmp_path / "cache"),
           "SHARD_ART": str(tmp_path / "model.rx"),
           "SHARD_REF": str(tmp_path / "ref.npy")}
    save = subprocess.run([sys.executable, "-c", _SAVE_SCRIPT], env=env,
                          capture_output=True, text=True, cwd=".",
                          timeout=900)
    assert "SAVE_OK" in save.stdout, save.stdout + save.stderr
    load = subprocess.run([sys.executable, "-c", _LOAD_SCRIPT], env=env,
                          capture_output=True, text=True, cwd=".",
                          timeout=900)
    assert "LOAD_OK" in load.stdout, load.stdout + load.stderr
