"""Per-architecture smoke + consistency tests (assignment requirement:
every assigned arch instantiates a reduced config and runs one
forward/train step on CPU, asserting shapes + no NaNs)."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.models import get_model
from repro.models.common import chunked_attention, decode_attention_jnp


def make_batch(cfg, b=2, s=32, key=1):
    rng = jax.random.PRNGKey(key)
    batch = {
        "tokens": jax.random.randint(rng, (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(rng, (b, s), 0, cfg.vocab),
    }
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            rng, (b, cfg.num_image_tokens, cfg.vit_dim))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(rng, (b, cfg.n_frames,
                                                  cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_loss(arch):
    cfg = get_config(arch, smoke=True)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits, _ = m.forward(params, batch)
    assert logits.shape == (2, 32, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    loss = m.loss(params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_train_step(arch):
    from repro.training import OptConfig, TrainConfig, init_state, \
        make_jitted_train_step
    cfg = get_config(arch, smoke=True)
    m = get_model(cfg)
    tc = TrainConfig(opt=OptConfig(lr=1e-3, total_steps=10, warmup_steps=1))
    state = init_state(m, jax.random.PRNGKey(0))
    step = make_jitted_train_step(m, tc, mesh=None, donate=False)
    state, metrics = step(state, make_batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state["opt"]["step"]) == 1


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_matches_forward_f32(arch):
    """prefill(s) + decode_step ≡ forward at every decode position, in
    f32 (bf16 differs by rounding; MoE needs full capacity)."""
    cfg = dataclasses.replace(get_config(arch, smoke=True),
                              dtype="float32", moe_cf=8.0)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b, s, extra = 2, 24, 3
    batch = make_batch(cfg, b, s + extra, key=2)
    full, _ = m.forward(params, batch)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :s]
    cache = m.init_cache(b, s + extra)
    lg, cache = m.prefill(params, pre, cache)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full[:, s - 1]),
                               rtol=1e-4, atol=1e-4)
    for t in range(extra):
        lg, cache = m.decode_step(params, cache,
                                  batch["tokens"][:, s + t:s + t + 1])
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, s + t]),
                                   rtol=1e-4, atol=2e-4,
                                   err_msg=f"decode position {t}")


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "deepseek-v3-671b",
                                  "mamba2-780m", "recurrentgemma-9b"])
def test_param_axes_matches_params(arch):
    """The logical-axes pytree must mirror the param pytree leaf-for-leaf
    with one axis name per array dim."""
    cfg = get_config(arch, smoke=True)
    m = get_model(cfg)
    params = jax.eval_shape(lambda: m.init(jax.random.PRNGKey(0)))
    axes = m.param_axes()
    is_ax = lambda x: isinstance(x, tuple)
    flat_p = jax.tree.leaves(params)
    flat_a = jax.tree.flatten(axes, is_leaf=is_ax)[0]
    assert len(flat_p) == len(flat_a)
    for p, a in zip(flat_p, flat_a):
        assert len(a) == p.ndim, (p.shape, a)


# ---------------------------------------------------------------------------
# attention equivalences
# ---------------------------------------------------------------------------
def naive_attention(q, k, v, causal=True, window=0):
    b, s, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, s, hkv, g, d)
    scores = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k) * d ** -0.5
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= i >= j
    if window:
        mask &= i - j < window
    scores = jnp.where(mask[None, :, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p, v)
    return out.reshape(b, s, h, d)


@pytest.mark.parametrize("window", [0, 7])
@pytest.mark.parametrize("chunks", [(8, 8), (16, 4), (5, 9)])
def test_chunked_attention_vs_naive(window, chunks, rng):
    b, s, h, hkv, d = 2, 23, 4, 2, 8
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    want = naive_attention(q, k, v, window=window)
    got = chunked_attention(q, k, v, causal=True,
                            window_arr=jnp.int32(window),
                            q_chunk=chunks[0], kv_chunk=chunks[1])
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               rtol=1e-5, atol=1e-5)


def test_chunked_attention_grads_finite(rng):
    b, s, h, d = 1, 16, 2, 8
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    f = lambda q, k, v: jnp.sum(chunked_attention(
        q, k, v, q_chunk=8, kv_chunk=8) ** 2)
    grads = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    for gr in grads:
        assert bool(jnp.isfinite(gr).all())


def test_decode_attention_jnp_vs_naive_last_row(rng):
    b, s, h, hkv, d = 2, 17, 4, 2, 8
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    want = naive_attention(q, k, v)[:, -1]
    got = decode_attention_jnp(q[:, -1], k, v,
                               jnp.full((b,), s, jnp.int32))
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               rtol=1e-5, atol=1e-5)
