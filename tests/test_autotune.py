"""Autotuner: tactic cache round-trips, off-mode bit-identity with the
heuristic selector, and corruption/staleness falling back instead of
crashing."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

import repro
from repro.api.options import CompileOptions
from repro.autotune import (TacticCache, Tactic, candidates_for_node,
                            environment_fingerprint, open_tactic_cache,
                            tactic_key, tune_selection)
from repro.core import ModelBuilder, select_kernels
from repro.kernels.tiles import (block_vmem_bytes, enumerate_blocks,
                                 pick_block, sublane_for,
                                 VMEM_BUDGET_BYTES)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mlp():
    mb = ModelBuilder().seed(0)
    x = mb.input((32,))
    h = mb.dense(x, 64, activation="relu")
    out = mb.dense(h, 8)
    return mb.build([out])


def _compile_tuned(graph, cache_dir, mode="full", budget_ms=10_000):
    return repro.compile(graph, CompileOptions(
        target="pallas", autotune=mode, autotune_budget_ms=budget_ms,
        cache_dir=str(cache_dir)))


# ---------------------------------------------------------------------------
# tiles: dtype-parametrized geometry (satellite)
# ---------------------------------------------------------------------------
def test_pick_block_f32_unchanged():
    # The f32 geometry is the pre-autotuner one, bit for bit.
    assert pick_block(1, 32, 2) == (8, 128, 128)
    assert pick_block(1000, 1000, 1000) == (256, 512, 256)
    assert pick_block(1000, 1000, 1000, itemsize=4) == (256, 512, 256)


def test_pick_block_bf16_uses_freed_budget():
    # Half the itemsize: sublane granule doubles, K cap doubles — the
    # working set stays inside the same VMEM budget instead of idling.
    bm, bk, bn = pick_block(1000, 4096, 1000, itemsize=2)
    assert bk == 1024
    assert bm % sublane_for(2) == 0
    assert block_vmem_bytes(bm, bk, bn, 2) <= VMEM_BUDGET_BYTES
    f32 = pick_block(1000, 4096, 1000, itemsize=4)
    assert block_vmem_bytes(*f32, itemsize=4) <= VMEM_BUDGET_BYTES
    assert bk > f32[1]


def test_sublane_for_matches_tpu_granules():
    assert sublane_for(4) == 8     # f32
    assert sublane_for(2) == 16    # bf16
    assert sublane_for(1) == 32    # int8


def test_enumerate_blocks_prior_first_and_vmem_legal():
    blocks = enumerate_blocks(512, 1024, 512)
    assert blocks[0] == pick_block(512, 1024, 512)
    assert len(blocks) == len(set(blocks)) > 1
    assert all(block_vmem_bytes(*b) <= VMEM_BUDGET_BYTES for b in blocks)
    # clipped to the padded problem dims on tiny shapes
    for bm, bk, bn in enumerate_blocks(1, 32, 2):
        assert bm <= 8 and bk <= 128 and bn <= 128


# ---------------------------------------------------------------------------
# autotune="off": bit-identical to the heuristic selector (acceptance)
# ---------------------------------------------------------------------------
def test_off_mode_matches_heuristic_on_all_table1_configs(rng):
    sys.path.insert(0, REPO)
    from benchmarks.table1_models import SUITE

    for name, build in SUITE.items():
        g = build()
        exe = repro.compile(g, CompileOptions(target="pallas"))
        exe.ensure_compiled(batch_size=1)
        # the selector runs on the optimized graph — compare against
        # exactly what the heuristic says for it
        heuristic = select_kernels(exe.graph, batch_size=1, target="pallas")
        sel = exe._selections.get(1, {})
        assert set(sel) == set(heuristic), name
        for node, choice in sel.items():
            assert choice.source == "heuristic", (name, node)
            assert choice.kernel == heuristic[node].kernel, (name, node)
            assert choice.reason == heuristic[node].reason, (name, node)
        assert "autotune" not in exe.cost_summary(), name


def test_off_mode_outputs_identical_to_default(rng):
    g = _mlp()
    x = rng.standard_normal((2, 32)).astype(np.float32)
    out = g.outputs[0]
    y_default = np.asarray(
        repro.compile(g, CompileOptions(target="pallas"))(input=x)[out])
    y_off = np.asarray(
        repro.compile(g, CompileOptions(target="pallas",
                                        autotune="off"))(input=x)[out])
    np.testing.assert_array_equal(y_default, y_off)


def test_options_validate_autotune_fields():
    with pytest.raises(ValueError):
        CompileOptions(autotune="always")
    with pytest.raises(ValueError):
        CompileOptions(autotune_budget_ms=0)
    # autotune knobs never change the options cache token (the resolved
    # selection is keyed separately)
    assert (CompileOptions(autotune="full").cache_token()
            == CompileOptions().cache_token())


# ---------------------------------------------------------------------------
# full mode: measured winners, budget, and the persistent cache
# ---------------------------------------------------------------------------
def test_full_mode_measures_and_reports(tmp_path, rng):
    g = _mlp()
    exe = _compile_tuned(g, tmp_path)
    x = rng.standard_normal((2, 32)).astype(np.float32)
    y = exe(input=x)
    cost = exe.cost_summary()
    sel = cost["kernel_selection"][2]
    dense = [c for c in sel if c["op"] == "dense"]
    assert dense and all(c["source"] == "measured" for c in dense)
    assert all(c["measured_us"] for c in dense)
    # every measured choice must name a candidate that was benchmarked
    for c in dense:
        assert any(lbl.split("[")[0] == c["kernel"]
                   for lbl in c["measured_us"])
    rep = cost["autotune"][2]
    assert rep["mode"] == "full" and rep["measured_nodes"]
    assert rep["cache"]["stores"] == len(rep["measured_nodes"])
    # numerics unchanged vs the oracle
    oracle = repro.compile(g, CompileOptions(target="interpret"))(input=x)
    np.testing.assert_allclose(
        np.asarray(y[g.outputs[0]]),
        np.asarray(oracle[g.outputs[0]]), rtol=2e-5, atol=2e-6)


def test_exhausted_budget_falls_back_to_heuristic(tmp_path):
    g = _mlp()
    exe = _compile_tuned(g, tmp_path, budget_ms=1e-3)
    exe.ensure_compiled(batch_size=1)
    sel = exe._selections[1]
    heuristic = select_kernels(exe.graph, batch_size=1, target="pallas")
    assert all(c.source == "heuristic" for c in sel.values())
    assert {n: c.kernel for n, c in sel.items()} == \
           {n: c.kernel for n, c in heuristic.items()}
    rep = exe.cost_summary()["autotune"][1]
    assert rep["heuristic_nodes"] and not rep["measured_nodes"]


def test_tactic_cache_round_trip_across_processes(tmp_path):
    """Process 1 measures and stores; process 2 compiles the same model
    and gets every tactic from the cache without re-benchmarking."""
    prog = """
import json, sys
sys.path.insert(0, {src!r})
import repro
from repro.api.options import CompileOptions
from repro.core import ModelBuilder
mb = ModelBuilder().seed(0)
x = mb.input((32,))
h = mb.dense(x, 64, activation="relu")
out = mb.dense(h, 8)
g = mb.build([out])
exe = repro.compile(g, CompileOptions(target="pallas", autotune="full",
                                      autotune_budget_ms=20000,
                                      cache_dir={cache!r}))
exe.ensure_compiled(batch_size=1)
print(json.dumps(exe.cost_summary()["autotune"][1]))
"""
    src = os.path.join(REPO, "src")
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    reports = []
    for _ in range(2):
        out = subprocess.run(
            [sys.executable, "-c",
             prog.format(src=src, cache=str(tmp_path))],
            capture_output=True, text=True, env=env, check=True)
        reports.append(json.loads(out.stdout.strip().splitlines()[-1]))
    first, second = reports
    assert first["measured_nodes"] == ["dense_1", "dense_3"]
    assert second["measured_nodes"] == []          # no re-benchmarking
    assert set(second["cached_nodes"]) == {"dense_1", "dense_3"}
    assert second["cache"]["hits"] == 2


def test_cached_mode_never_measures(tmp_path):
    g = _mlp()
    exe = _compile_tuned(g, tmp_path, mode="cached")
    exe.ensure_compiled(batch_size=1)
    rep = exe.cost_summary()["autotune"][1]
    assert not rep["measured_nodes"] and rep["cache"]["stores"] == 0
    assert all(c.source == "heuristic"
               for c in exe._selections[1].values())


# ---------------------------------------------------------------------------
# corruption / staleness: heuristic fallback, never a crash (satellite)
# ---------------------------------------------------------------------------
def _populate_cache(tmp_path):
    g = _mlp()
    exe = _compile_tuned(g, tmp_path)
    exe.ensure_compiled(batch_size=1)
    tactics_dir = os.path.join(str(tmp_path), "tactics")
    files = [os.path.join(tactics_dir, f) for f in os.listdir(tactics_dir)]
    assert files
    return g, files


def test_corrupt_tactic_entries_fall_back(tmp_path):
    g, files = _populate_cache(tmp_path)
    for f in files:
        with open(f, "w") as fh:
            fh.write("{not json")
    exe = _compile_tuned(g, tmp_path, mode="cached")
    exe.ensure_compiled(batch_size=1)
    assert all(c.source == "heuristic"
               for c in exe._selections[1].values())
    # every corrupt entry the compile probed is dropped so it stops
    # costing a parse.  (Kernel-tactic entries measured under the tuned
    # graph's geometry are never probed once the corrupted graph-level
    # decisions fall back to the heuristic pipeline, so a strict "all
    # gone" doesn't hold — but the probed majority must be.)
    tactics_dir = os.path.dirname(files[0])
    remaining = [f for f in os.listdir(tactics_dir) if f.endswith(".json")]
    assert len(remaining) < len(files)


def test_stale_fingerprint_entries_ignored(tmp_path):
    g, files = _populate_cache(tmp_path)
    for f in files:
        with open(f) as fh:
            entry = json.load(fh)
        entry["fingerprint"] = "0" * 64   # measured in another world
        with open(f, "w") as fh:
            json.dump(entry, fh)
    exe = _compile_tuned(g, tmp_path, mode="cached")
    exe.ensure_compiled(batch_size=1)
    assert all(c.source == "heuristic"
               for c in exe._selections[1].values())
    # stale-but-parseable entries are kept (valid for their writer)
    assert os.path.exists(files[0])


def test_malformed_winner_entry_falls_back(tmp_path):
    g, files = _populate_cache(tmp_path)
    fp = environment_fingerprint()
    for f in files:
        with open(f, "w") as fh:
            json.dump({"winner": 42, "fingerprint": fp}, fh)
    exe = _compile_tuned(g, tmp_path, mode="cached")
    exe.ensure_compiled(batch_size=1)
    assert all(c.source == "heuristic"
               for c in exe._selections[1].values())


# ---------------------------------------------------------------------------
# plumbing details
# ---------------------------------------------------------------------------
def test_tactic_key_depends_on_desc_and_fingerprint():
    d1 = {"op": "dense", "m": 8, "k": 32, "n": 64}
    d2 = {"op": "dense", "m": 8, "k": 32, "n": 128}
    assert tactic_key(d1) == tactic_key(d1)
    assert tactic_key(d1) != tactic_key(d2)
    assert tactic_key(d1) != tactic_key(d1, fingerprint="f" * 64)


def test_candidates_shared_shapes_measured_once(tmp_path):
    # Two dense layers with identical geometry share one measurement.
    mb = ModelBuilder().seed(0)
    x = mb.input((64,))
    h = mb.dense(x, 64, activation="relu")
    h = mb.dense(h, 64, activation="relu")
    out = mb.dense(h, 64, activation="relu")
    g = mb.build([out])
    cache = open_tactic_cache(str(tmp_path))
    heuristic = select_kernels(g, batch_size=1, target="pallas")
    tuned, rep = tune_selection(g, heuristic, batch_size=1,
                                precision="exact", mode="full",
                                budget_ms=20_000, cache=cache)
    # all three dense layers are 64->64 relu — one measurement, two
    # memo hits (activations under exact precision have nothing to tune)
    assert len(rep["measured_nodes"]) == 1
    assert len(rep["cached_nodes"]) == 2
    assert all(tuned[n].source == "measured"
               for n, c in heuristic.items() if c.op == "dense")


def test_executable_cache_key_tracks_selection(tmp_path):
    g = _mlp()
    exe = repro.compile(g, CompileOptions(target="pallas"))
    heuristic_key = exe._key(1, select_kernels(g, batch_size=1,
                                               target="pallas"))
    measured = {
        n: repro.core.KernelChoice(c.node, c.op, "lax.dot", "measured",
                                   source="measured")
        for n, c in select_kernels(g, batch_size=1,
                                   target="pallas").items()}
    assert exe._key(1, measured) != heuristic_key
    # same resolved selection -> same key, regardless of autotune mode
    exe2 = repro.compile(g, CompileOptions(target="pallas",
                                           autotune="cached"))
    assert exe2._key(1, select_kernels(g, batch_size=1,
                                       target="pallas")) == heuristic_key
